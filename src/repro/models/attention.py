"""Attention with three interchangeable implementations.

  * ``naive``    — O(S^2) materialised scores; the oracle.
  * ``chunked``  — work-list-scheduled flash attention in pure lax with a
                   custom VJP (FlashAttention-2 algebra).  This is the exact
                   CPU/dry-run twin of the Pallas kernel in
                   ``repro.kernels.flash_attention``: the static work list of
                   (q_tile, kv_tile) pairs plays the role of the Pallas grid,
                   so tile-skipping optimisations map 1:1 between the two.
  * ``pallas``   — the TPU kernel (dispatched in kernels/flash_attention/ops).

GQA is handled by grouping query heads over KV heads (no KV materialised
repeat).  Masking is position-based: callers pass q/kv position arrays;
invalid KV slots are marked with position -1.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_NEG = -1.0e30


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    causal: bool = True
    window: int = 0              # 0 = unbounded; else sliding window size
    q_chunk: int = 512
    kv_chunk: int = 512
    skip_masked_tiles: bool = False   # hillclimb: drop fully-masked tiles
    # static hint that q/kv positions are arange(0..S) (self-attention);
    # required for skip_masked_tiles work-list filtering.
    positions_are_arange: bool = False


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------


def _tile_mask(spec: AttnSpec, q_pos: Array, kv_pos: Array) -> Array:
    """q_pos (B, cq), kv_pos (B, ck) -> bool (B, cq, ck)."""
    qp = q_pos[:, :, None]
    kp = kv_pos[:, None, :]
    m = kp >= 0
    if spec.causal:
        m = m & (kp <= qp)
    if spec.window:
        m = m & (kp > qp - spec.window)
    return m


# ---------------------------------------------------------------------------
# Naive oracle
# ---------------------------------------------------------------------------


def naive_attention(q: Array, k: Array, v: Array, *, spec: AttnSpec,
                    q_pos: Array, kv_pos: Array) -> Array:
    """q (B,Sq,H,D), k/v (B,Skv,KH,D) -> (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    qg = q.reshape(B, Sq, KH, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) / np.sqrt(D)
    mask = _tile_mask(spec, q_pos, kv_pos)[:, None, None]      # (B,1,1,Sq,Skv)
    s = jnp.where(mask, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Work list (the "grid")
# ---------------------------------------------------------------------------


def build_worklist(spec: AttnSpec, n_q: int, n_kv: int) -> np.ndarray:
    """Static (n_pairs, 2) array of (q_tile, kv_tile) indices."""
    pairs = []
    for qi in range(n_q):
        for kj in range(n_kv):
            if spec.skip_masked_tiles and spec.positions_are_arange:
                q_lo, q_hi = qi * spec.q_chunk, (qi + 1) * spec.q_chunk - 1
                k_lo, k_hi = kj * spec.kv_chunk, (kj + 1) * spec.kv_chunk - 1
                if spec.causal and k_lo > q_hi:
                    continue                       # entirely above diagonal
                if spec.window and k_hi <= q_lo - spec.window:
                    continue                       # entirely out of window
            pairs.append((qi, kj))
    return np.asarray(pairs, dtype=np.int32)


# ---------------------------------------------------------------------------
# Flash attention (lax work-list scan) with custom VJP
# ---------------------------------------------------------------------------


def _slice_t(x: Array, i: Array, chunk: int) -> Array:
    """Slice chunk i along axis 1 (time)."""
    return jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=1)


def _flash_fwd_impl(spec: AttnSpec, q, k, v, q_pos, kv_pos):
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    assert Sq % spec.q_chunk == 0 and Skv % spec.kv_chunk == 0, (Sq, Skv, spec)
    wl = build_worklist(spec, Sq // spec.q_chunk, Skv // spec.kv_chunk)
    scale = 1.0 / np.sqrt(D)

    acc0 = jnp.zeros((B, Sq, KH, G, D), jnp.float32)
    m0 = jnp.full((B, Sq, KH, G), _NEG, jnp.float32)
    l0 = jnp.zeros((B, Sq, KH, G), jnp.float32)

    def body(carry, idx):
        acc, m, l = carry
        qi, kj = idx[0], idx[1]
        qc = _slice_t(q, qi, spec.q_chunk).reshape(B, spec.q_chunk, KH, G, D)
        kc = _slice_t(k, kj, spec.kv_chunk)
        vc = _slice_t(v, kj, spec.kv_chunk)
        qp = _slice_t(q_pos, qi, spec.q_chunk)
        kp = _slice_t(kv_pos, kj, spec.kv_chunk)

        s = jnp.einsum("bqkgd,bskd->bkgqs", qc, kc,
                       preferred_element_type=jnp.float32) * scale
        msk = _tile_mask(spec, qp, kp)[:, None, None]
        s = jnp.where(msk, s, _NEG)

        mc = jax.lax.dynamic_slice_in_dim(m, qi * spec.q_chunk, spec.q_chunk, 1)
        lc = jax.lax.dynamic_slice_in_dim(l, qi * spec.q_chunk, spec.q_chunk, 1)
        ac = jax.lax.dynamic_slice_in_dim(acc, qi * spec.q_chunk, spec.q_chunk, 1)
        # carried layout (B, cq, KH, G); tile layout (B, KH, G, cq, ck)
        mc_t = mc.transpose(0, 2, 3, 1)
        m_new = jnp.maximum(mc_t, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(msk, p, 0.0)
        corr = jnp.exp(mc_t - m_new)
        l_new = lc.transpose(0, 2, 3, 1) * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), vc,
                        preferred_element_type=jnp.float32)
        a_new = ac * corr.transpose(0, 3, 1, 2)[..., None] + pv

        acc = jax.lax.dynamic_update_slice_in_dim(acc, a_new, qi * spec.q_chunk, 1)
        m = jax.lax.dynamic_update_slice_in_dim(
            m, m_new.transpose(0, 3, 1, 2), qi * spec.q_chunk, 1)
        l = jax.lax.dynamic_update_slice_in_dim(
            l, l_new.transpose(0, 3, 1, 2), qi * spec.q_chunk, 1)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.asarray(wl))
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).reshape(B, Sq, H, D).astype(q.dtype)
    lse = (m + jnp.log(l_safe)).reshape(B, Sq, H)
    return out, lse


def _flash_bwd_impl(spec: AttnSpec, q, k, v, q_pos, kv_pos, out, lse, dout):
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    wl = build_worklist(spec, Sq // spec.q_chunk, Skv // spec.kv_chunk)
    scale = 1.0 / np.sqrt(D)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                                    # (B,Sq,H)
    lse_g = lse.reshape(B, Sq, KH, G)
    delta_g = delta.reshape(B, Sq, KH, G)

    dq0 = jnp.zeros((B, Sq, KH, G, D), jnp.float32)
    dk0 = jnp.zeros((B, Skv, KH, D), jnp.float32)
    dv0 = jnp.zeros((B, Skv, KH, D), jnp.float32)

    def body(carry, idx):
        dq, dk, dv = carry
        qi, kj = idx[0], idx[1]
        qc = _slice_t(q, qi, spec.q_chunk).reshape(B, spec.q_chunk, KH, G, D)
        kc = _slice_t(k, kj, spec.kv_chunk)
        vc = _slice_t(v, kj, spec.kv_chunk)
        doc = _slice_t(dout, qi, spec.q_chunk).reshape(B, spec.q_chunk, KH, G, D)
        qp = _slice_t(q_pos, qi, spec.q_chunk)
        kp = _slice_t(kv_pos, kj, spec.kv_chunk)
        lsec = _slice_t(lse_g, qi, spec.q_chunk).transpose(0, 2, 3, 1)
        deltc = _slice_t(delta_g, qi, spec.q_chunk).transpose(0, 2, 3, 1)

        s = jnp.einsum("bqkgd,bskd->bkgqs", qc, kc,
                       preferred_element_type=jnp.float32) * scale
        msk = _tile_mask(spec, qp, kp)[:, None, None]
        p = jnp.exp(jnp.where(msk, s, _NEG) - lsec[..., None])
        p = jnp.where(msk, p, 0.0)                               # (B,KH,G,cq,ck)

        dvc = jnp.einsum("bkgqs,bqkgd->bskd", p, doc.astype(jnp.float32))
        dp = jnp.einsum("bqkgd,bskd->bkgqs", doc, vc,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - deltc[..., None]) * scale
        dqc = jnp.einsum("bkgqs,bskd->bqkgd", ds, kc,
                         preferred_element_type=jnp.float32)
        dkc = jnp.einsum("bkgqs,bqkgd->bskd", ds, qc.astype(jnp.float32))

        prev = jax.lax.dynamic_slice_in_dim(dq, qi * spec.q_chunk, spec.q_chunk, 1)
        dq = jax.lax.dynamic_update_slice_in_dim(dq, prev + dqc, qi * spec.q_chunk, 1)
        prev = jax.lax.dynamic_slice_in_dim(dk, kj * spec.kv_chunk, spec.kv_chunk, 1)
        dk = jax.lax.dynamic_update_slice_in_dim(dk, prev + dkc, kj * spec.kv_chunk, 1)
        prev = jax.lax.dynamic_slice_in_dim(dv, kj * spec.kv_chunk, spec.kv_chunk, 1)
        dv = jax.lax.dynamic_update_slice_in_dim(dv, prev + dvc, kj * spec.kv_chunk, 1)
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(body, (dq0, dk0, dv0), jnp.asarray(wl))
    return (dq.reshape(B, Sq, H, D).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def flash_attention(spec: AttnSpec, q, k, v, q_pos, kv_pos):
    out, _ = _flash_fwd_impl(spec, q, k, v, q_pos, kv_pos)
    return out


def _fa_fwd(spec, q, k, v, q_pos, kv_pos):
    out, lse = _flash_fwd_impl(spec, q, k, v, q_pos, kv_pos)
    return out, (q, k, v, q_pos, kv_pos, out, lse)


def _fa_bwd(spec, res, dout):
    q, k, v, q_pos, kv_pos, out, lse = res
    dq, dk, dv = _flash_bwd_impl(spec, q, k, v, q_pos, kv_pos, out, lse, dout)
    return dq, dk, dv, None, None


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
# Decode attention (Sq == 1): plain masked einsum — no S^2 term exists.
# ---------------------------------------------------------------------------


def decode_attention(q: Array, k: Array, v: Array, *, q_pos: Array,
                     kv_pos: Array, window: int = 0) -> Array:
    """q (B,1,H,D); k/v (B,S,KH,D); q_pos (B,1); kv_pos (B,S)."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    qg = q.reshape(B, Sq, KH, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) / np.sqrt(D)
    spec = AttnSpec(causal=True, window=window)
    mask = _tile_mask(spec, q_pos, kv_pos)[:, None, None]
    s = jnp.where(mask, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------


def attention(q, k, v, *, impl: str, spec: AttnSpec, q_pos, kv_pos):
    if impl == "naive":
        return naive_attention(q, k, v, spec=spec, q_pos=q_pos, kv_pos=kv_pos)
    if impl == "chunked":
        # clamp chunk sizes to divisors of the sequence lengths
        def _divisor_chunk(want: int, length: int) -> int:
            c = min(want, length)
            while length % c:
                c -= 1
            return c

        spec = dataclasses.replace(
            spec,
            q_chunk=_divisor_chunk(spec.q_chunk, q.shape[1]),
            kv_chunk=_divisor_chunk(spec.kv_chunk, k.shape[1]),
        )
        return flash_attention(spec, q, k, v, q_pos, kv_pos)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                                      causal=spec.causal, window=spec.window)
    raise ValueError(f"unknown attention impl {impl!r}")
