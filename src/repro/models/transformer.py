"""Model assembly for all assigned architecture families.

Layer stacks are ``lax.scan`` over stacked weights (HLO size O(1) in depth).
Three entry points per family, built by factories so cfg/flags stay static:

  * ``loss_fn``      — full-sequence forward + CE loss           (train_4k)
  * ``prefill``      — full-sequence forward -> (last_logits, cache)
  * ``decode_step``  — one token with cache                      (decode_*)

Sharding: params carry logical axes resolved in repro/sharding/specs.py.
The embedding lookup is vocab-parallel via shard_map (a plain gather on a
vocab-sharded table would make GSPMD all-gather the table); the CE loss uses
an iota-compare fused reduction, so neither end materialises (B,S,V) one-hots
nor cross-shard gathers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers, mamba2, moe as moe_lib, rwkv6
from jax.ad_checkpoint import checkpoint_name

from repro.models.attention import AttnSpec, attention, decode_attention

Array = jax.Array


# ---------------------------------------------------------------------------
# Run-time knobs (the hillclimb levers) and sharding context
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RunFlags:
    attn_impl: str = "chunked"          # naive | chunked | pallas
    q_chunk: int = 512
    kv_chunk: int = 512
    skip_masked_tiles: bool = False     # hillclimb: causal tile skipping
    microbatches: int = 1               # grad-accumulation microbatches
    remat: bool = True
    moe_mode: str = "pjit"              # pjit | ep_shardmap (hillclimb)
    moe_seq_chunk: int = 2048           # chunk S for MoE dispatch (prefill
                                        # memory bound; 0 = no chunking)
    scan_layers: bool = True
    compute_dtype: str = "bfloat16"     # bfloat16 | float32 (oracle mode)
    wkv_chunk: int = 16                 # RWKV WKV chunk length (hillclimb)
    remat_policy: str = "full"          # full | save_block_io (hillclimb)
    sequence_parallel: bool = False     # Megatron-SP activations (hillclimb)


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Any
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"

    @property
    def data_spec(self):
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]


def _constrain(x, ctx: Optional[ShardCtx], *spec):
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(ctx.mesh, P(*spec)))


def padded_vocab(cfg: ModelConfig) -> int:
    return -(-cfg.vocab_size // 16) * 16


def cast_params(params, dtype=jnp.bfloat16):
    """Compute-dtype cast (differentiable, so f32 masters get f32 grads)."""
    return jax.tree.map(
        lambda a: a.astype(dtype) if a.dtype == jnp.float32 else a, params)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _attn_init(key, cfg: ModelConfig, dtype, pre=()):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "wq": layers.dense_init(ks[0], d, cfg.d_q, dtype, shape_prefix=pre),
        "wk": layers.dense_init(ks[1], d, cfg.d_kv, dtype, shape_prefix=pre),
        "wv": layers.dense_init(ks[2], d, cfg.d_kv, dtype, shape_prefix=pre),
        "wo": layers.dense_init(ks[3], cfg.d_q, d, dtype, shape_prefix=pre),
    }


def init_params(cfg: ModelConfig, key: Array, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    Vp = padded_vocab(cfg)
    params: dict = {"final_norm": jnp.ones((d,), jnp.float32)}
    if cfg.frontend != "frames":
        params["embed"] = layers.embed_init(ks[0], Vp, d, dtype)
    if not cfg.tie_embeddings or cfg.frontend == "frames":
        params["lm_head"] = layers.dense_init(ks[1], d, Vp, dtype)

    L = cfg.n_layers
    fam = cfg.family
    if fam in ("dense", "audio", "moe"):
        blocks = {
            "attn": _attn_init(ks[2], cfg, dtype, pre=(L,)),
            "ln1": jnp.ones((L, d), jnp.float32),
            "ln2": jnp.ones((L, d), jnp.float32),
        }
        if cfg.moe is not None:
            blocks["moe"] = moe_lib.moe_init(ks[3], cfg, L, dtype)
        else:
            blocks["mlp"] = layers.mlp_init(ks[3], d, cfg.d_ff, cfg.mlp_type,
                                            dtype, shape_prefix=(L,))
        params["blocks"] = blocks
    elif fam == "vlm":
        n_cross = L // cfg.cross_attn_period
        per = cfg.cross_attn_period - 1
        assert n_cross * cfg.cross_attn_period == L
        params["blocks"] = {
            "attn": _attn_init(ks[2], cfg, dtype, pre=(n_cross, per)),
            "mlp": layers.mlp_init(ks[3], d, cfg.d_ff, cfg.mlp_type, dtype,
                                   shape_prefix=(n_cross, per)),
            "ln1": jnp.ones((n_cross, per, d), jnp.float32),
            "ln2": jnp.ones((n_cross, per, d), jnp.float32),
            "cross": {
                **_attn_init(ks[4], cfg, dtype, pre=(n_cross,)),
                "ln_q": jnp.ones((n_cross, d), jnp.float32),
                "gate": jnp.zeros((n_cross,), jnp.float32),
                "mlp": layers.mlp_init(ks[5], d, cfg.d_ff, cfg.mlp_type,
                                       dtype, shape_prefix=(n_cross,)),
                "ln2": jnp.ones((n_cross, d), jnp.float32),
                "gate_mlp": jnp.zeros((n_cross,), jnp.float32),
            },
        }
    elif fam == "hybrid":
        n_super = L // cfg.attn_period
        per = cfg.attn_period - 1
        assert n_super * cfg.attn_period == L
        params["blocks"] = {
            "mamba": mamba2.mamba2_init(ks[2], cfg, dtype,
                                        shape_prefix=(n_super, per)),
            "mamba_ln": jnp.ones((n_super, per, d), jnp.float32),
            "shared": {
                "attn": _attn_init(ks[3], cfg, dtype),
                "mlp": layers.mlp_init(ks[4], d, cfg.d_ff, cfg.mlp_type, dtype),
                "ln1": jnp.ones((d,), jnp.float32),
                "ln2": jnp.ones((d,), jnp.float32),
            },
        }
    elif fam == "ssm":
        params["blocks"] = {
            "rwkv": rwkv6.rwkv6_init(ks[2], cfg, dtype, shape_prefix=(L,)),
            "ln1": jnp.ones((L, d), jnp.float32),
            "ln2": jnp.ones((L, d), jnp.float32),
        }
    else:
        raise ValueError(fam)
    return params


# ---------------------------------------------------------------------------
# Embedding / head (vocab-parallel when ctx is given)
# ---------------------------------------------------------------------------


def embed_lookup(cfg: ModelConfig, params, ids: Array,
                 ctx: Optional[ShardCtx]) -> Array:
    table = params["embed"]
    if ctx is None:
        return jnp.take(table, ids, axis=0)

    def body(tab, ids_l):
        start = jax.lax.axis_index(ctx.model_axis) * tab.shape[0]
        loc = ids_l - start
        ok = (loc >= 0) & (loc < tab.shape[0])
        emb = jnp.take(tab, jnp.clip(loc, 0, tab.shape[0] - 1), axis=0)
        emb = jnp.where(ok[..., None], emb, jnp.zeros((), emb.dtype))
        return jax.lax.psum(emb, ctx.model_axis)

    ax = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    dsize = 1
    for a in ctx.data_axes:
        dsize *= ax[a]
    bspec = ctx.data_spec if ids.shape[0] % dsize == 0 and \
        ids.shape[0] >= dsize else None
    ids_spec = P(bspec, *([None] * (ids.ndim - 1)))
    out_spec = P(bspec, *([None] * ids.ndim))
    return jax.shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(ctx.model_axis, None), ids_spec),
        out_specs=out_spec, check_vma=False)(table, ids)


def lm_logits(cfg: ModelConfig, params, x: Array,
              ctx: Optional[ShardCtx]) -> Array:
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings and cfg.frontend != "frames":
        logits = x @ params["embed"].T.astype(x.dtype)
    else:
        logits = x @ params["lm_head"].astype(x.dtype)
    if ctx is not None:
        spec = [None] * logits.ndim
        spec[0] = ctx.data_spec
        spec[-1] = ctx.model_axis
        logits = _constrain(logits, ctx, *spec)
    return logits


# ---------------------------------------------------------------------------
# Attention block (dense / moe / audio / vlm / hybrid-shared)
# ---------------------------------------------------------------------------


def _qkv(cfg, w, x, pos):
    B, S, _ = x.shape
    q = (x @ w["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = (x @ w["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ w["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    q = layers.apply_rope(q, pos, cfg.rope)
    k = layers.apply_rope(k, pos, cfg.rope)
    return q, k, v


def attn_block(cfg, flags: RunFlags, ctx, w, ln, x, pos, *, window=0,
               return_kv=False):
    h = layers.rms_norm(x, ln, cfg.norm_eps)
    q, k, v = _qkv(cfg, w, h, pos)
    spec = AttnSpec(causal=cfg.causal, window=window, q_chunk=flags.q_chunk,
                    kv_chunk=flags.kv_chunk,
                    skip_masked_tiles=flags.skip_masked_tiles,
                    positions_are_arange=True)
    o = attention(q, k, v, impl=flags.attn_impl, spec=spec, q_pos=pos,
                  kv_pos=pos)
    B, S, _ = x.shape
    out = x + checkpoint_name(
        o.reshape(B, S, cfg.d_q) @ w["wo"], "attn_out")
    if return_kv:
        # cache copies are sequence-sharded on the model axis (context-
        # parallel decode layout) so the stacked prefill cache is /16 per
        # device rather than replicated along S
        if ctx is not None and S % 16 == 0:
            k = _constrain(k, ctx, ctx.data_spec, ctx.model_axis, None, None)
            v = _constrain(v, ctx, ctx.data_spec, ctx.model_axis, None, None)
        return out, (k, v)
    return out


def attn_block_decode(cfg, w, ln, x, q_pos, kcache, vcache, kv_pos, *,
                      window=0):
    """x (B,1,d); kcache/vcache (B,S,KH,hd) already containing this token."""
    h = layers.rms_norm(x, ln, cfg.norm_eps)
    B = x.shape[0]
    q = (h @ w["wq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
    q = layers.apply_rope(q, q_pos, cfg.rope)
    o = decode_attention(q, kcache, vcache, q_pos=q_pos, kv_pos=kv_pos,
                         window=window)
    return x + o.reshape(B, 1, cfg.d_q) @ w["wo"]


def mlp_block(cfg, w, ln, x):
    h = layers.rms_norm(x, ln, cfg.norm_eps)
    return x + checkpoint_name(
        layers.mlp_apply(w, h, cfg.mlp_type), "mlp_out")


def moe_block(cfg, flags, ctx, w_moe, ln, x, layer_idx):
    B, S, d = x.shape
    h = layers.rms_norm(x, ln, cfg.norm_eps)

    def apply_tokens(ht):                       # ht (T, d)
        if flags.moe_mode == "ep_shardmap" and ctx is not None:
            from repro.sharding.ep import moe_apply_ep
            return moe_apply_ep(w_moe, ht, cfg, ctx)
        return moe_lib.moe_apply(w_moe, ht, cfg)

    ch = flags.moe_seq_chunk
    if ch and S > ch and S % ch == 0:
        # chunk the sequence dim so dispatch buffers stay bounded at 32k+
        # prefill (S stays unsharded -> clean chunk slicing under GSPMD)
        nc = S // ch
        hc = h.reshape(B, nc, ch, d).transpose(1, 0, 2, 3)

        def body(aux, hi):
            y, a = apply_tokens(hi.reshape(B * ch, d))
            return aux + a, y.reshape(B, ch, d)

        aux, ys = jax.lax.scan(body, jnp.zeros((), jnp.float32), hc)
        y = ys.transpose(1, 0, 2, 3).reshape(B, S, d)
        aux = aux / nc
    else:
        y, aux = apply_tokens(h.reshape(B * S, d))
        y = y.reshape(B, S, d)
    return x + y, aux


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill) per family
# ---------------------------------------------------------------------------


def _maybe_remat(fn, flags: RunFlags):
    if not flags.remat:
        return fn
    if flags.remat_policy == "save_block_io":
        # keep post-all-reduce block outputs resident: the rematerialised
        # forward then re-runs only local math, not the TP collectives
        policy = jax.checkpoint_policies.save_only_these_names(
            "attn_out", "mlp_out")
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def forward(cfg: ModelConfig, params, batch: dict, flags: RunFlags,
            ctx: Optional[ShardCtx], *, collect_cache: bool = False):
    """Returns (hidden (B,S,d), aux_losses, cache_parts or None)."""
    fam = cfg.family
    cdt = jnp.dtype(flags.compute_dtype)
    if cfg.frontend == "frames":
        x = batch["frames"].astype(cdt)
        B, S = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = x + layers.sinusoidal_positions(pos, cfg.d_model).astype(x.dtype)
    else:
        ids = batch["tokens"]
        B, S = ids.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = embed_lookup(cfg, params, ids, ctx).astype(cdt)
    seq_axis = "model" if flags.sequence_parallel else None
    x = _constrain(x, ctx, ctx.data_spec if ctx else None, seq_axis, None)

    bl = params["blocks"]
    aux = jnp.zeros((), jnp.float32)
    cache = None

    if fam in ("dense", "audio", "moe"):
        has_moe = cfg.moe is not None

        def body(carry, wl):
            x, aux = carry
            if collect_cache:
                x, (k, v) = attn_block(cfg, flags, ctx, wl["attn"], wl["ln1"],
                                       x, pos, return_kv=True)
            else:
                x = attn_block(cfg, flags, ctx, wl["attn"], wl["ln1"], x, pos)
            if has_moe:
                x, a = moe_block(cfg, flags, ctx, wl["moe"], wl["ln2"], x, None)
                aux = aux + a
            else:
                x = mlp_block(cfg, wl["mlp"], wl["ln2"], x)
            x = _constrain(x, ctx, ctx.data_spec if ctx else None,
                           seq_axis, None)
            if collect_cache:
                return (x, aux), (k, v)
            return (x, aux), None

        (x, aux), kv = jax.lax.scan(_maybe_remat(body, flags), (x, aux), bl)
        if collect_cache:
            cache = {"k": kv[0], "v": kv[1]}                  # (L,B,S,KH,hd)

    elif fam == "vlm":
        patches = batch["patches"].astype(cdt)                # (B,M,d)
        M = patches.shape[1]
        ppos = jnp.broadcast_to(jnp.arange(M, dtype=jnp.int32),
                                (patches.shape[0], M))

        def super_body(carry, wl):
            x, aux = carry

            def inner(x, wi):
                if collect_cache:
                    x, (k, v) = attn_block(cfg, flags, ctx, wi["attn"],
                                           wi["ln1"], x, pos, return_kv=True)
                    x = mlp_block(cfg, wi["mlp"], wi["ln2"], x)
                    return x, (k, v)
                x = attn_block(cfg, flags, ctx, wi["attn"], wi["ln1"], x, pos)
                x = mlp_block(cfg, wi["mlp"], wi["ln2"], x)
                return x, None

            x, inner_kv = jax.lax.scan(
                _maybe_remat(inner, flags), x,
                {"attn": wl["attn"], "mlp": wl["mlp"], "ln1": wl["ln1"],
                 "ln2": wl["ln2"]})
            # cross-attention to patch embeddings (non-causal, gated)
            cw = wl["cross"]
            h = layers.rms_norm(x, cw["ln_q"], cfg.norm_eps)
            B_, S_, _ = x.shape
            q = (h @ cw["wq"]).reshape(B_, S_, cfg.n_heads, cfg.head_dim)
            k = (patches @ cw["wk"]).reshape(B_, M, cfg.n_kv_heads, cfg.head_dim)
            v = (patches @ cw["wv"]).reshape(B_, M, cfg.n_kv_heads, cfg.head_dim)
            spec = AttnSpec(causal=False, q_chunk=flags.q_chunk,
                            kv_chunk=flags.kv_chunk)
            o = attention(q, k, v, impl=flags.attn_impl, spec=spec,
                          q_pos=pos, kv_pos=ppos)
            x = x + jnp.tanh(cw["gate"]).astype(x.dtype) * (
                o.reshape(B_, S_, cfg.d_q) @ cw["wo"])
            h = layers.rms_norm(x, cw["ln2"], cfg.norm_eps)
            x = x + jnp.tanh(cw["gate_mlp"]).astype(x.dtype) * \
                layers.mlp_apply(cw["mlp"], h, cfg.mlp_type)
            if collect_cache:
                return (x, aux), (inner_kv, (k, v))
            return (x, aux), None

        (x, aux), ys = jax.lax.scan(super_body, (x, aux), bl)
        if collect_cache:
            (sk, sv), (ck, cv) = ys            # sk: (n_cross, per, B, S, KH, hd)
            n_self = sk.shape[0] * sk.shape[1]
            cache = {"k": sk.reshape((n_self,) + sk.shape[2:]),
                     "v": sv.reshape((n_self,) + sv.shape[2:]),
                     "cross_k": ck, "cross_v": cv}

    elif fam == "hybrid":
        shared = bl["shared"]

        def super_body(carry, wl):
            x, aux = carry

            def inner(x, wi):
                h = layers.rms_norm(x, wi["ln"], cfg.norm_eps)
                y, (st, tails) = mamba2.mamba2_forward(wi["w"], h, cfg)
                return x + y, (st, tails)

            x, states = jax.lax.scan(
                _maybe_remat(inner, flags), x,
                {"w": wl["mamba"], "ln": wl["mamba_ln"]})
            if collect_cache:
                x, (k, v) = attn_block(cfg, flags, ctx, shared["attn"],
                                       shared["ln1"], x, pos,
                                       window=cfg.attn_window, return_kv=True)
            else:
                x = attn_block(cfg, flags, ctx, shared["attn"], shared["ln1"],
                               x, pos, window=cfg.attn_window)
            x = mlp_block(cfg, shared["mlp"], shared["ln2"], x)
            if collect_cache:
                W = min(cfg.attn_window or x.shape[1], x.shape[1])
                return (x, aux), (states, (k[:, -W:], v[:, -W:]))
            return (x, aux), None

        xs_hy = {"mamba": bl["mamba"], "mamba_ln": bl["mamba_ln"]}
        (x, aux), ys = jax.lax.scan(super_body, (x, aux), xs_hy)
        if collect_cache:
            states, (kw, vw) = ys
            cache = {"mamba_state": states[0], "conv_tails": states[1],
                     "win_k": kw, "win_v": vw}

    elif fam == "ssm":
        def body(carry, wl):
            x, aux = carry
            w = wl["rwkv"]
            h = layers.rms_norm(x, wl["ln1"], cfg.norm_eps)
            B_, S_, d_ = h.shape
            H, K = cfg.n_heads, cfg.rwkv.head_size
            state0 = jnp.zeros((B_, H, K, K), jnp.float32)
            shift0 = jnp.zeros((B_, 1, d_), h.dtype)
            y, tshift, tstate = rwkv6.time_mix(w["tmix"], h, cfg, shift0,
                                               state0, chunk=flags.wkv_chunk)
            x = x + y
            h = layers.rms_norm(x, wl["ln2"], cfg.norm_eps)
            y, cshift = rwkv6.channel_mix(w["cmix"], h, shift0)
            x = x + y
            if collect_cache:
                return (x, aux), (tshift, tstate, cshift)
            return (x, aux), None

        (x, aux), ys = jax.lax.scan(_maybe_remat(body, flags), (x, aux), bl)
        if collect_cache:
            cache = {"tmix_shift": ys[0], "wkv_state": ys[1],
                     "cmix_shift": ys[2]}
    else:
        raise ValueError(fam)

    return x, aux, cache


# ---------------------------------------------------------------------------
# Loss (train), prefill, decode factories
# ---------------------------------------------------------------------------


def make_loss_fn(cfg: ModelConfig, flags: RunFlags, ctx: Optional[ShardCtx]):
    def loss_fn(params, batch):
        params = cast_params(params, jnp.dtype(flags.compute_dtype))
        x, aux, _ = forward(cfg, params, batch, flags, ctx)
        logits = lm_logits(cfg, params, x, ctx)
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        loss, _ = layers.softmax_cross_entropy(logits, labels, mask)
        return loss + 0.01 * aux, {"loss": loss, "aux": aux}
    return loss_fn


def make_prefill_fn(cfg: ModelConfig, flags: RunFlags, ctx: Optional[ShardCtx],
                    max_len: int):
    """Returns fn(params, batch) -> (last_logits (B,Vp), cache dict)."""
    def prefill(params, batch):
        params = cast_params(params, jnp.dtype(flags.compute_dtype))
        x, _, parts = forward(cfg, params, batch, flags, ctx,
                              collect_cache=True)
        logits = lm_logits(cfg, params, x[:, -1:], ctx)[:, 0]
        B, S = x.shape[0], x.shape[1]
        cache = _grow_cache(cfg, parts, B, S, max_len)
        return logits, cache
    return prefill


def _grow_cache(cfg, parts, B, S, max_len):
    """Pad prefill-collected cache parts out to max_len and add bookkeeping."""
    fam = cfg.family
    pos = jnp.full((B,), S, jnp.int32)                        # next position
    out = dict(parts or {})
    if "k" in out:                                            # dense/moe/vlm/audio
        pad = max_len - S
        out["k"] = jnp.pad(out["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        out["v"] = jnp.pad(out["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        out["kv_pos"] = jnp.concatenate([
            jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S)),
            jnp.full((B, pad), -1, jnp.int32)], axis=1)
    if fam == "hybrid":
        W = out["win_k"].shape[2]
        # Align window cache to the decode ring-slot convention slot = pos % W:
        # the collected slice holds positions S-W..S-1 at indices 0..W-1, so
        # roll by (S - W) % W to place position p at index p % W.
        shift = (S - W) % W
        out["win_k"] = jnp.roll(out["win_k"], shift, axis=2)
        out["win_v"] = jnp.roll(out["win_v"], shift, axis=2)
        out["win_pos"] = jnp.roll(jnp.broadcast_to(
            jnp.arange(S - W, S, dtype=jnp.int32), out["win_k"].shape[:3]
        ).astype(jnp.int32), shift, axis=2)
    out["pos"] = pos
    return out


def init_cache(cfg: ModelConfig, B: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Empty cache for pure-decode dry-runs and serving."""
    fam = cfg.family
    pos = jnp.zeros((B,), jnp.int32)
    if fam in ("dense", "audio", "moe"):
        L, KH, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        return {
            "k": jnp.zeros((L, B, max_len, KH, hd), dtype),
            "v": jnp.zeros((L, B, max_len, KH, hd), dtype),
            "kv_pos": jnp.full((B, max_len), -1, jnp.int32),
            "pos": pos,
        }
    if fam == "vlm":
        L, KH, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        n_cross = L // cfg.cross_attn_period
        n_self = L - n_cross
        return {
            "k": jnp.zeros((n_self, B, max_len, KH, hd), dtype),
            "v": jnp.zeros((n_self, B, max_len, KH, hd), dtype),
            "kv_pos": jnp.full((B, max_len), -1, jnp.int32),
            "cross_k": jnp.zeros((n_cross, B, cfg.n_media_tokens, KH, hd), dtype),
            "cross_v": jnp.zeros((n_cross, B, cfg.n_media_tokens, KH, hd), dtype),
            "pos": pos,
        }
    if fam == "hybrid":
        n_super = cfg.n_layers // cfg.attn_period
        per = cfg.attn_period - 1
        d_in, H, Pd, N = mamba2.dims(cfg)
        cw = cfg.ssm.conv_width
        W = min(cfg.attn_window or max_len, max_len)
        return {
            "mamba_state": jnp.zeros((n_super, per, B, H, Pd, N), jnp.float32),
            "conv_tails": (
                jnp.zeros((n_super, per, B, cw - 1, d_in), dtype),
                jnp.zeros((n_super, per, B, cw - 1, N), dtype),
                jnp.zeros((n_super, per, B, cw - 1, N), dtype),
            ),
            "win_k": jnp.zeros((n_super, B, W, cfg.n_kv_heads, cfg.head_dim), dtype),
            "win_v": jnp.zeros((n_super, B, W, cfg.n_kv_heads, cfg.head_dim), dtype),
            "win_pos": jnp.full((n_super, B, W), -1, jnp.int32),
            "pos": pos,
        }
    if fam == "ssm":
        L, H, K = cfg.n_layers, cfg.n_heads, cfg.rwkv.head_size
        d = cfg.d_model
        return {
            "tmix_shift": jnp.zeros((L, B, 1, d), dtype),
            "wkv_state": jnp.zeros((L, B, H, K, K), jnp.float32),
            "cmix_shift": jnp.zeros((L, B, 1, d), dtype),
            "pos": pos,
        }
    raise ValueError(fam)


_CACHE_BATCH_AXIS = {
    "k": 1, "v": 1, "cross_k": 1, "cross_v": 1, "kv_pos": 0, "pos": 0,
    "mamba_state": 2, "conv_tails": 2, "win_k": 1, "win_v": 1, "win_pos": 1,
    "tmix_shift": 1, "wkv_state": 1, "cmix_shift": 1,
}


def cache_insert(cache: dict, single: dict, slot: int) -> dict:
    """Insert a batch-1 cache (from prefill) into slot `slot` of a batched
    cache — the continuous-batching primitive used by repro/serving."""
    def one(path, big, small):
        name = None
        for p in path:
            k = getattr(p, "key", None)
            if isinstance(k, str) and k in _CACHE_BATCH_AXIS:
                name = k
        ax = _CACHE_BATCH_AXIS.get(name, 0)
        idx = [slice(None)] * big.ndim
        idx[ax] = slot
        small_idx = [slice(None)] * small.ndim
        small_idx[ax] = 0
        return big.at[tuple(idx)].set(small[tuple(small_idx)].astype(big.dtype))

    return jax.tree_util.tree_map_with_path(one, cache, single)


def make_decode_fn(cfg: ModelConfig, flags: RunFlags,
                   ctx: Optional[ShardCtx]):
    """Returns fn(params, cache, tokens (B,)) -> (logits (B,Vp), cache)."""

    def decode(params, cache, tokens):
        params = cast_params(params, jnp.dtype(flags.compute_dtype))
        B = tokens.shape[0]
        pos = cache["pos"]                                    # (B,)
        qpos = pos[:, None]
        x = embed_lookup(cfg, params, tokens[:, None], ctx).astype(
            jnp.dtype(flags.compute_dtype))
        bl = params["blocks"]
        fam = cfg.family
        barange = jnp.arange(B)

        if fam in ("dense", "audio", "moe", "vlm"):
            kc, vc = cache["k"], cache["v"]                   # (L,B,S,KH,hd)
            kv_pos = cache["kv_pos"].at[barange, pos].set(pos)
            S = kc.shape[2]

            if fam == "vlm":
                n_cross = cfg.n_layers // cfg.cross_attn_period
                per = cfg.cross_attn_period - 1

                def super_body(carry, xs):
                    x, kc, vc = carry
                    wl, ci = xs

                    def inner(carry2, xs2):
                        x, kc, vc = carry2
                        wi, li = xs2
                        x, kc, vc = _decode_attn_layer(
                            cfg, wi, x, qpos, kc, vc, kv_pos, li, pos, barange)
                        x = mlp_block(cfg, wi["mlp"], wi["ln2"], x)
                        return (x, kc, vc), None

                    lidx = ci * per + jnp.arange(per)   # flattened self-layer idx
                    (x, kc, vc), _ = jax.lax.scan(
                        inner, (x, kc, vc),
                        ({"attn": wl["attn"], "mlp": wl["mlp"],
                          "ln1": wl["ln1"], "ln2": wl["ln2"]}, lidx))
                    cw = wl["cross"]
                    h = layers.rms_norm(x, cw["ln_q"], cfg.norm_eps)
                    q = (h @ cw["wq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
                    ck, cv = cache["cross_k"][ci], cache["cross_v"][ci]
                    M = ck.shape[1]
                    # non-causal cross attention: q_pos=0, kv_pos=0 everywhere
                    o = decode_attention(q, ck, cv,
                                         q_pos=jnp.zeros((B, 1), jnp.int32),
                                         kv_pos=jnp.zeros((B, M), jnp.int32))
                    x = x + jnp.tanh(cw["gate"]).astype(x.dtype) * (
                        o.reshape(B, 1, cfg.d_q) @ cw["wo"])
                    h = layers.rms_norm(x, cw["ln2"], cfg.norm_eps)
                    x = x + jnp.tanh(cw["gate_mlp"]).astype(x.dtype) * \
                        layers.mlp_apply(cw["mlp"], h, cfg.mlp_type)
                    return (x, kc, vc), None

                (x, kc, vc), _ = jax.lax.scan(
                    super_body, (x, kc, vc),
                    (bl, jnp.arange(n_cross)))
            else:
                has_moe = cfg.moe is not None

                def body(carry, xs):
                    x, kc, vc = carry
                    wl, li = xs
                    x, kc, vc = _decode_attn_layer(
                        cfg, wl, x, qpos, kc, vc, kv_pos, li, pos, barange)
                    if has_moe:
                        x, _ = moe_block(cfg, flags, ctx, wl["moe"], wl["ln2"],
                                         x, None)
                    else:
                        x = mlp_block(cfg, wl["mlp"], wl["ln2"], x)
                    return (x, kc, vc), None

                (x, kc, vc), _ = jax.lax.scan(
                    body, (x, kc, vc), (bl, jnp.arange(cfg.n_layers)))

            new_cache = dict(cache, k=kc, v=vc, kv_pos=kv_pos, pos=pos + 1)

        elif fam == "hybrid":
            shared = bl["shared"]
            W = cache["win_k"].shape[2]
            slot = pos % W
            win_pos = cache["win_pos"]

            def super_body(carry, xs):
                x = carry
                wl, st, tails, wk, wv, wp = xs

                def inner(carry2, xs2):
                    x = carry2
                    wi, st_i, tails_i = xs2
                    h = layers.rms_norm(x, wi["ln"], cfg.norm_eps)
                    y, (st2, tails2) = mamba2.mamba2_decode(
                        wi["w"], h, cfg, st_i, tails_i)
                    return x + y, (st2, tails2)

                x, (st2, tails2) = jax.lax.scan(
                    inner, x, ({"w": wl["mamba"], "ln": wl["mamba_ln"]},
                               st, tails))
                # shared attention with ring-buffer window cache
                h = layers.rms_norm(x, shared["ln1"], cfg.norm_eps)
                k1 = (h @ shared["attn"]["wk"]).reshape(B, 1, cfg.n_kv_heads,
                                                        cfg.head_dim)
                v1 = (h @ shared["attn"]["wv"]).reshape(B, 1, cfg.n_kv_heads,
                                                        cfg.head_dim)
                k1 = layers.apply_rope(k1, qpos, cfg.rope)
                wk = wk.at[barange, slot].set(k1[:, 0])
                wv = wv.at[barange, slot].set(v1[:, 0])
                wp = wp.at[barange, slot].set(pos)
                x = attn_block_decode(cfg, shared["attn"], shared["ln1"], x,
                                      qpos, wk, wv, wp, window=cfg.attn_window)
                x = mlp_block(cfg, shared["mlp"], shared["ln2"], x)
                return x, (st2, tails2, wk, wv, wp)

            xs_hy = {"mamba": bl["mamba"], "mamba_ln": bl["mamba_ln"]}
            x, ys = jax.lax.scan(
                super_body, x,
                (xs_hy, cache["mamba_state"], cache["conv_tails"],
                 cache["win_k"], cache["win_v"], cache["win_pos"]))
            st2, tails2, wk, wv, wp = ys
            new_cache = dict(cache, mamba_state=st2, conv_tails=tails2,
                             win_k=wk, win_v=wv, win_pos=wp, pos=pos + 1)

        elif fam == "ssm":
            def body(carry, xs):
                x = carry
                wl, tsh, wst, csh = xs
                w = wl["rwkv"]
                h = layers.rms_norm(x, wl["ln1"], cfg.norm_eps)
                y, tsh2, wst2 = rwkv6.time_mix(w["tmix"], h, cfg, tsh, wst)
                x = x + y
                h = layers.rms_norm(x, wl["ln2"], cfg.norm_eps)
                y, csh2 = rwkv6.channel_mix(w["cmix"], h, csh)
                return x + y, (tsh2, wst2, csh2)

            x, ys = jax.lax.scan(
                body, x, (bl, cache["tmix_shift"], cache["wkv_state"],
                          cache["cmix_shift"]))
            new_cache = dict(cache, tmix_shift=ys[0], wkv_state=ys[1],
                             cmix_shift=ys[2], pos=pos + 1)
        else:
            raise ValueError(fam)

        logits = lm_logits(cfg, params, x, ctx)[:, 0]
        return logits, new_cache

    return decode


def _decode_attn_layer(cfg, wl, x, qpos, kc, vc, kv_pos, li, pos, barange):
    """Project k/v for this token, write into layer li of the cache, attend.

    The scatter is applied to a per-layer slice, then dynamic-update-sliced
    back into the carried stack: scattering directly into the (L, ...) stack
    makes XLA-CPU materialise a whole-cache f32 copy (scatter dtype
    promotion), which wrecks the dry-run memory fit; the slice bound keeps
    that artifact to one layer while the carry DUS stays in place."""
    h = layers.rms_norm(x, wl["ln1"], cfg.norm_eps)
    B = x.shape[0]
    k1 = (h @ wl["attn"]["wk"]).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
    v1 = (h @ wl["attn"]["wv"]).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
    k1 = layers.apply_rope(k1, qpos, cfg.rope)
    kc_l = jax.lax.dynamic_index_in_dim(kc, li, 0, keepdims=False)
    vc_l = jax.lax.dynamic_index_in_dim(vc, li, 0, keepdims=False)
    kc_l = kc_l.at[barange, pos].set(k1[:, 0].astype(kc_l.dtype))
    vc_l = vc_l.at[barange, pos].set(v1[:, 0].astype(vc_l.dtype))
    kc = jax.lax.dynamic_update_slice_in_dim(kc, kc_l[None], li, 0)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, vc_l[None], li, 0)
    x = attn_block_decode(cfg, wl["attn"], wl["ln1"], x, qpos, kc_l, vc_l,
                          kv_pos)
    return x, kc, vc
