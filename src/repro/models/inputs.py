"""Concrete example batches (tests/examples) and abstract input specs
(dry-run) for every (arch x shape) cell.

The modality frontends are STUBS per the assignment: audio provides
precomputed frame embeddings, vision provides precomputed patch embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def make_train_batch(cfg: ModelConfig, B: int, S: int, key) -> dict:
    ks = jax.random.split(key, 4)
    batch = {}
    V = cfg.vocab_size
    if cfg.frontend == "frames":
        batch["frames"] = jax.random.normal(ks[0], (B, S, cfg.d_model),
                                            jnp.float32) * 0.02
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, V, jnp.int32)
    if cfg.frontend == "tokens+patches":
        batch["patches"] = jax.random.normal(
            ks[1], (B, cfg.n_media_tokens, cfg.d_model), jnp.float32) * 0.02
    batch["labels"] = jax.random.randint(ks[2], (B, S), 0, V, jnp.int32)
    return batch


def make_prefill_batch(cfg: ModelConfig, B: int, S: int, key) -> dict:
    b = make_train_batch(cfg, B, S, key)
    b.pop("labels")
    return b


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    batch = {}
    if cfg.frontend == "frames":
        batch["frames"] = sd((B, S, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = sd((B, S), jnp.int32)
    if cfg.frontend == "tokens+patches":
        batch["patches"] = sd((B, cfg.n_media_tokens, cfg.d_model), jnp.float32)
    batch["labels"] = sd((B, S), jnp.int32)
    return batch


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b = train_input_specs(cfg, shape)
    b.pop("labels")
    return b


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig):
    return jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
