"""Reusable matmul co-verification sweep pieces (paper Fig. 5 cells).

One firmware + one backend table for the systolic matmul, shared by the
quickstart preflight, the Fig. 5 sweep benchmark, and the scheduler tests
so the three stay in lockstep.  The firmware signature matches
core/scheduler.CoVerifySession: ``firmware(fb, op, backend, **config)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.systolic_matmul import ops as mm_ops, ref as mm_ref
from repro.kernels.systolic_matmul.kernel import matmul as mm_kernel


def matmul_firmware(fb, op, backend, *, size, tile: int = 32):
    """Host-side program for one sweep cell: alloc/seed DDR, launch the
    matmul with its per-tile burst list (§IV data-movement contract)."""
    rng = np.random.default_rng(size)
    a = rng.normal(size=(size, size)).astype(np.float32)
    b = rng.normal(size=(size, size)).astype(np.float32)
    fb.mem.alloc("a", a.shape, np.float32)
    fb.mem.alloc("b", b.shape, np.float32)
    fb.mem.alloc("c", (size, size), np.float32)
    fb.mem.host_write("a", a)
    fb.mem.host_write("b", b)
    fb.launch(op, backend, ["a", "b"], ["c"],
              burst_list=lambda: mm_ops.transactions(
                  size, size, size, bm=tile, bn=tile, bk=tile,
                  dtype_bytes=4))


def matmul_fabric_firmware(fab, op, backend, *, size, tile: int = 32):
    """Sharded fabric counterpart of ``matmul_firmware`` (same seeded data,
    same host buffer names): row-shard A/C across the cluster, broadcast B
    — the ``sharding/specs.py`` "systolic_matmul" fabric layout — then
    gather C.  K is never split, so the gathered C is bit-identical to the
    single-device launch of the same backend.
    """
    from repro.core.fabric import sharded_launch
    from repro.sharding.specs import FABRIC_OP_SPECS

    rng = np.random.default_rng(size)
    a = rng.normal(size=(size, size)).astype(np.float32)
    b = rng.normal(size=(size, size)).astype(np.float32)
    sharded_launch(
        fab, op, backend,
        inputs={"a": a, "b": b},
        output=("c", (size, size), np.float32),
        specs=FABRIC_OP_SPECS["systolic_matmul"],
        burst_list=lambda dev, shapes: mm_ops.transactions(
            shapes["c"][0], size, size,
            bm=min(tile, shapes["c"][0]), bn=tile, bk=tile, dtype_bytes=4))


def matmul_backends(tile: int = 32, jit: bool = True) -> dict:
    """oracle/interpret/compiled backend table for register_op.

    With ``jit`` the interpret and compiled backends are jitted ONCE at
    table-creation time — registering one table per CoVerifySession is
    what makes traces/executables cache across sweep cells; re-creating
    the table per cell (the sequential baseline) re-pays tracing.
    """
    oracle = lambda x, y: np.asarray(mm_ref.matmul_ref(jnp.asarray(x),
                                                       jnp.asarray(y)))
    if not jit:
        return dict(
            oracle=oracle,
            interpret=lambda x, y: np.asarray(mm_kernel(
                jnp.asarray(x), jnp.asarray(y), bm=tile, bn=tile, bk=tile,
                interpret=True)),
            compiled=oracle)
    jit_interp = jax.jit(lambda x, y: mm_kernel(
        x, y, bm=tile, bn=tile, bk=tile, interpret=True))
    jit_mm = jax.jit(lambda x, y: mm_ref.matmul_ref(x, y))
    return dict(
        oracle=oracle,
        interpret=lambda x, y: np.asarray(jit_interp(jnp.asarray(x),
                                                     jnp.asarray(y))),
        compiled=lambda x, y: np.asarray(jit_mm(jnp.asarray(x),
                                                jnp.asarray(y))))
