"""Jit wrapper + static transaction-stream derivation for the FireBridge
memory bridge (the kernel's BlockSpec schedule IS its DMA burst list)."""
from __future__ import annotations

from typing import List, Tuple

import jax

from repro.kernels.systolic_matmul.kernel import matmul as _matmul


def matmul(a, b, *, bm=128, bn=128, bk=128, out_dtype=None):
    return _matmul(a, b, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
                   interpret=jax.default_backend() != "tpu")


def transactions(M: int, N: int, K: int, *, bm=128, bn=128, bk=128,
                 dtype_bytes: int = 2) -> List[Tuple[str, str, int, int]]:
    """Static HBM<->VMEM transaction stream implied by the BlockSpecs.

    Returns [(engine, direction, address, nbytes)] in grid order — the
    TPU-side analogue of the AXI burst list FireBridge logs from its DMA
    VIPs (§IV).  Fed to core/transactions.py for Fig. 8/9-style profiling
    and arbitrated online by the congestion LinkModel (§IV-C) when the
    bridge runs with a CongestionConfig.
    """
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    txs: List[Tuple[str, str, int, int]] = []
    a_base, b_base = 0, M * K * dtype_bytes
    c_base = b_base + K * N * dtype_bytes
    for m in range(M // bm):
        for n in range(N // bn):
            for k in range(K // bk):
                txs.append(("dma_a", "read",
                            a_base + (m * (K // bk) + k) * bm * bk * dtype_bytes,
                            bm * bk * dtype_bytes))
                txs.append(("dma_b", "read",
                            b_base + (k * (N // bn) + n) * bk * bn * dtype_bytes,
                            bk * bn * dtype_bytes))
            txs.append(("dma_c", "write",
                        c_base + (m * (N // bn) + n) * bm * bn * dtype_bytes,
                        bm * bn * dtype_bytes))
    return txs
