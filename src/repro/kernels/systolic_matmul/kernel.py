"""Blocked matmul kernel — the TPU adaptation of the paper's representative
systolic-array accelerator (paper §V-B, Fig. 4).

The paper's SoC streams A/B tiles through AXI DMAs into a weight-stationary
systolic array.  On TPU the MXU *is* the systolic array; the analogue of the
DMA burst schedule is the BlockSpec index map, and the analogue of the AXI
transaction stream is the (statically derivable) sequence of HBM->VMEM tile
fetches.  ops.py exposes that transaction stream to the FireBridge memory
bridge so the same firmware-profiling flow as the paper's Fig. 8/9 runs
against this kernel.

Grid (nm, nn, nk), k minor-most: the f32 VMEM accumulator persists across
the k sweep; C is written once per (m, n) tile — max data reuse, one C
writeback, exactly like an output-stationary systolic schedule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc_s, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_s[...] = jnp.zeros_like(acc_s)

    acc_s[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_s[...].astype(o_ref.dtype)


def matmul(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128,
           interpret: bool = True, out_dtype=None):
    """a (M,K) @ b (K,N) -> (M,N) with explicit VMEM tiling."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (a.shape, b.shape)
    grid = (M // bm, N // bn, K // bk)
    out_dtype = out_dtype or a.dtype
    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
