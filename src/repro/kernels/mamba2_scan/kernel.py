"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid (B, H/hb, L/cl) with the chunk index minor-most: the (hb, P, N) f32
state lives in VMEM scratch and is carried across chunks — HBM traffic is
exactly one read of x/dt/B/C and one write of y (+ one final state write),
vs. the lax twin whose per-chunk state round-trips through HBM.

All exponent arguments are <= 0 (SSD property), so the kernel is
overflow-safe in f32 without rescaling tricks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, B_ref, C_ref, A_ref, D_ref, y_ref, st_ref,
                state_s, *, nc: int, cl: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_s[...] = jnp.zeros_like(state_s)

    x = x_ref[0].astype(jnp.float32)          # (cl, hb, P)
    dt = dt_ref[0].astype(jnp.float32)        # (cl, hb)
    B_ = B_ref[0].astype(jnp.float32)         # (cl, N)
    C_ = C_ref[0].astype(jnp.float32)         # (cl, N)
    A = A_ref[...].astype(jnp.float32)        # (hb,)
    D = D_ref[...].astype(jnp.float32)        # (hb,)
    state = state_s[...]                      # (hb, P, N)

    dA = dt * A[None, :]                      # (cl, hb) <= 0
    cum = jnp.cumsum(dA, axis=0)
    CB = jax.lax.dot_general(C_, B_, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (cl, cl)
    seg = cum[:, None, :] - cum[None, :, :]   # (cl, cl, hb), i >= j ok
    ii = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 1)
    causal = (jj <= ii)[:, :, None]
    M = CB[:, :, None] * jnp.where(causal, jnp.exp(jnp.where(causal, seg, 0.0)), 0.0)
    M = M * dt[None, :, :]                    # weight by dt_j
    # y_intra[i,h,p] = sum_j M[i,j,h] x[j,h,p]  (batched over h)
    Mh = M.transpose(2, 0, 1)                 # (hb, cl, cl)
    xh = x.transpose(1, 0, 2)                 # (hb, cl, P)
    y_h = jax.lax.dot_general(Mh, xh, (((2,), (1,)), ((0,), (0,))),
                              preferred_element_type=jnp.float32)  # (hb,cl,P)
    # y_inter[i,h,p] = exp(cum[i,h]) * sum_n C[i,n] state[h,p,n]
    Cst = jax.lax.dot_general(
        C_, state.reshape(state.shape[0] * state.shape[1], state.shape[2]),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    Cst = Cst.reshape(cl, state.shape[0], state.shape[1])  # (cl, hb, P)
    y = y_h.transpose(1, 0, 2) + Cst * jnp.exp(cum)[:, :, None]
    y = y + D[None, :, None] * x
    y_ref[0] = y.astype(y_ref.dtype)

    # state update
    decay_end = jnp.exp(cum[-1])              # (hb,)
    w = dt * jnp.exp(cum[-1][None, :] - cum)  # (cl, hb)
    xw = (x * w[:, :, None]).transpose(1, 2, 0)         # (hb, P, cl)
    upd = jax.lax.dot_general(xw, B_, (((2,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (hb,P,N)
    state_s[...] = state * decay_end[:, None, None] + upd

    @pl.when(c == nc - 1)
    def _done():
        st_ref[0] = state_s[...]


def ssd_scan(x, dt, B_, C_, A, D, *, chunk: int = 128, hb: int = 8,
             interpret: bool = True):
    """x (B,L,H,P); dt (B,L,H) f32; B_/C_ (B,L,N); A/D (H,) f32.
    Returns (y (B,L,H,P) f32, final_state (B,H,P,N) f32)."""
    B, L, H, P = x.shape
    N = B_.shape[-1]
    cl = min(chunk, L)
    hb = min(hb, H)
    assert L % cl == 0 and H % hb == 0
    grid = (B, H // hb, L // cl)
    y, st = pl.pallas_call(
        functools.partial(_ssd_kernel, nc=grid[2], cl=cl),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cl, hb, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, cl, hb), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, cl, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, cl, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((hb,), lambda b, h, c: (h,)),
            pl.BlockSpec((hb,), lambda b, h, c: (h,)),
        ],
        out_specs=[
            pl.BlockSpec((1, cl, hb, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, hb, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hb, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, B_, C_, A, D)
    return y, st
