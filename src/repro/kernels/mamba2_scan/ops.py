"""Jit wrapper for the SSD scan kernel with backend dispatch."""
from __future__ import annotations

import jax

from repro.kernels.mamba2_scan.kernel import ssd_scan as _ssd_scan


def ssd_scan(x, dt, B_, C_, A, D, *, chunk=128, hb=8):
    return _ssd_scan(x, dt, B_, C_, A, D, chunk=chunk, hb=hb,
                     interpret=jax.default_backend() != "tpu")
