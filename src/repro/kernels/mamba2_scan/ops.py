"""Jit wrapper for the SSD scan kernel with backend dispatch, plus the
static per-tile DMA burst list implied by its BlockSpec grid (the §IV
"schedule is the burst list" contract; consumed by the FireBridge memory
bridge and the online congestion link, Fig. 8)."""
from __future__ import annotations

from typing import List, Tuple

import jax

from repro.kernels.mamba2_scan.kernel import ssd_scan as _ssd_scan


def ssd_scan(x, dt, B_, C_, A, D, *, chunk=128, hb=8):
    return _ssd_scan(x, dt, B_, C_, A, D, chunk=chunk, hb=hb,
                     interpret=jax.default_backend() != "tpu")


def transactions(B: int, L: int, H: int, P: int, N: int, *,
                 chunk: int = 128, hb: int = 8,
                 dtype_bytes: int = 4) -> List[Tuple[str, str, int, int]]:
    """Per-tile HBM bursts of the SSD scan grid (B, H/hb, L/chunk).

    Per grid cell: one x/dt/B/C chunk fetch each and one y chunk write;
    per (batch, head-group) one final-state writeback.  The VMEM-resident
    state never round-trips — exactly the kernel's locality win, visible
    here as the absence of dma_state traffic inside the chunk sweep.
    """
    chunk = min(chunk, L)
    x_base = 0
    dt_base = x_base + B * L * H * P * dtype_bytes
    b_base = dt_base + B * L * H * dtype_bytes
    c_base = b_base + B * L * N * dtype_bytes
    y_base = c_base + B * L * N * dtype_bytes
    s_base = y_base + B * L * H * P * dtype_bytes
    x_tile = chunk * hb * P * dtype_bytes
    dt_tile = chunk * hb * dtype_bytes
    bc_tile = chunk * N * dtype_bytes
    state = hb * P * N * dtype_bytes
    txs: List[Tuple[str, str, int, int]] = []
    for b in range(B):
        for g in range(max(1, H // hb)):
            for c in range(L // chunk):
                off = ((b * max(1, H // hb) + g) * (L // chunk) + c)
                txs.append(("dma_x", "read", x_base + off * x_tile, x_tile))
                txs.append(("dma_dt", "read",
                            dt_base + off * dt_tile, dt_tile))
                bc_off = (b * (L // chunk) + c) * bc_tile
                txs.append(("dma_bc", "read", b_base + bc_off, bc_tile))
                txs.append(("dma_bc", "read", c_base + bc_off, bc_tile))
                txs.append(("dma_y", "write", y_base + off * x_tile, x_tile))
            txs.append(("dma_state", "write",
                        s_base + (b * max(1, H // hb) + g) * state, state))
    return txs
