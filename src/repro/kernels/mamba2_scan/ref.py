"""Per-timestep recurrence oracle for the SSD scan kernel (exact, slow)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, B_, C_, A, D):
    """x (B,L,H,P); dt (B,L,H); B_/C_ (B,L,N); A/D (H,).
    state_t = state * exp(dt_t A) + dt_t * x_t outer B_t;
    y_t = C_t . state_t + D * x_t."""
    Bsz, L, H, P = x.shape
    N = B_.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B_.astype(jnp.float32)
    Cf = C_.astype(jnp.float32)

    def step(state, t):
        xt, dtt, bt, ct = xf[:, t], dtf[:, t], Bf[:, t], Cf[:, t]
        decay = jnp.exp(dtt * A[None, :])                       # (B,H)
        state = state * decay[..., None, None] + jnp.einsum(
            "bn,bhp->bhpn", bt, xt * dtt[..., None])
        y = jnp.einsum("bn,bhpn->bhp", ct, state) + D[None, :, None] * xt
        return state, y

    state0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    state, ys = jax.lax.scan(step, state0, jnp.arange(L))
    return ys.transpose(1, 0, 2, 3), state
