"""Reusable flash-attention co-verification sweep pieces (kernel layout
B,H,S,D), mirroring kernels/systolic_matmul/sweep.py: one firmware + one
backend table shared by the scheduler tests, the fabric scaling benchmark,
and the cluster example, plus the head-sharded fabric firmware.

Heads are independent in attention, so the fabric layout
(sharding/specs.py "flash_attention": shard q/k/v/o on H) gathers to a
bit-identical result vs the single-device launch whenever the device
count divides both H and KH.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as R


def _inputs(batch: int, heads: int, seq: int, dim: int):
    """Seeded kernel-layout q/k/v (MHA: KH == H, so any device count that
    divides H shards exactly)."""
    rng = np.random.default_rng(batch * 7919 + heads * 101 + seq + dim)
    q = rng.normal(size=(batch, heads, seq, dim)).astype(np.float32)
    k = rng.normal(size=(batch, heads, seq, dim)).astype(np.float32)
    v = rng.normal(size=(batch, heads, seq, dim)).astype(np.float32)
    return q, k, v


def flash_backends(bq: int = 32, bk: int = 32, causal: bool = True,
                   jit: bool = True) -> dict:
    """oracle/interpret/compiled backend table for register_op.

    oracle = jnp reference, interpret = Pallas kernel in interpret mode
    ("RTL sim"), compiled = jitted reference (XLA deployment tier).
    """
    def oracle(q, k, v):
        return np.asarray(R.attention_ref(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal))

    def interp_raw(q, k, v):
        out, _ = K.flash_fwd(q, k, v, causal=causal, window=0, bq=bq, bk=bk,
                             interpret=True)
        return out

    if not jit:
        return dict(
            oracle=oracle,
            interpret=lambda q, k, v: np.asarray(
                interp_raw(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))),
            compiled=oracle)
    jit_interp = jax.jit(interp_raw)
    jit_ref = jax.jit(lambda q, k, v: R.attention_ref(q, k, v,
                                                      causal=causal))
    return dict(
        oracle=oracle,
        interpret=lambda q, k, v: np.asarray(jit_interp(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))),
        compiled=lambda q, k, v: np.asarray(jit_ref(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))))


def flash_firmware(fb, op, backend, *, batch=1, heads=8, seq=64, dim=16,
                   bq: int = 32, bk: int = 32):
    """Single-device host program: alloc/seed q/k/v/o DDR buffers, launch
    with the BlockSpec-derived per-tile burst list (§IV contract)."""
    q, k, v = _inputs(batch, heads, seq, dim)
    for name, arr in (("q", q), ("k", k), ("v", v)):
        fb.mem.alloc(name, arr.shape, np.float32)
        fb.mem.host_write(name, arr)
    fb.mem.alloc("o", q.shape, np.float32)
    fb.launch(op, backend, ["q", "k", "v"], ["o"],
              burst_list=lambda: fa_ops.transactions(
                  batch, heads, seq, seq, dim, bq=bq, bk=bk, causal=True,
                  dtype_bytes=4))


def flash_fabric_firmware(fab, op, backend, *, batch=1, heads=8, seq=64,
                          dim=16, bq: int = 32, bk: int = 32):
    """Head-sharded fabric counterpart of ``flash_firmware`` (same seeded
    data, same host buffer names): scatter q/k/v on H, device-local
    launches with shard-sized burst lists, gather o on H."""
    from repro.core.fabric import sharded_launch
    from repro.sharding.specs import FABRIC_OP_SPECS

    if heads % fab.n:
        raise ValueError(f"device count {fab.n} must divide heads {heads}")
    q, k, v = _inputs(batch, heads, seq, dim)
    sharded_launch(
        fab, op, backend,
        inputs={"q": q, "k": k, "v": v},
        output=("o", q.shape, np.float32),
        specs=FABRIC_OP_SPECS["flash_attention"],
        burst_list=lambda dev, shapes: fa_ops.transactions(
            batch, shapes["q"][1], seq, seq, dim, bq=bq, bk=bk, causal=True,
            dtype_bytes=4))
