"""Pallas TPU flash-attention kernels (fwd + dkdv/dq bwd).

Layout: (B, H, S, D) with D = head_dim on the 128-lane minor dim and S tiled
in MXU-friendly multiples of 8/128.  Grid iteration on TPU is row-major
(minor-most fastest), so for grid (b, h, i, j) the VMEM scratch carries the
online-softmax state across the j (KV-block) sweep of a fixed q block — the
exact schedule of the lax work-list twin in repro/models/attention.py.

GQA is handled in the index maps (k/v block index h // G); no KV repeat is
ever materialised.  Causal/window tiles that are fully masked are skipped
via predication (pl.when), the kernel-side equivalent of the work-list
``skip_masked_tiles`` flag.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG = -1.0e30


def _tile_mask(i, j, bq, bk, causal: bool, window: int):
    """(bq, bk) bool mask for q block i, kv block j (positions are arange)."""
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        m = m & (kpos <= qpos)
    if window:
        m = m & (kpos > qpos - window)
    return m


def _tile_live(i, j, bq, bk, causal: bool, window: int):
    """Scalar predicate: does tile (i, j) contain any unmasked element?"""
    live = jnp.bool_(True)
    if causal:
        live = live & (j * bk <= i * bq + bq - 1)
    if window:
        live = live & ((j + 1) * bk - 1 > i * bq - window)
    return live


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_s, l_s, acc_s, *,
                causal: bool, window: int, bq: int, bk: int, nk: int,
                scale: float):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    @pl.when(_tile_live(i, j, bq, bk, causal, window))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _tile_mask(i, j, bq, bk, causal, window)
        s = jnp.where(mask, s, NEG)
        m_prev = m_s[...]                                # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)                   # (bq, 1)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, 0] = (acc_s[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_s[...] + jnp.log(l))[:, 0]


def flash_fwd(q, k, v, *, causal: bool, window: int = 0, bq: int = 512,
              bk: int = 512, interpret: bool = True):
    """q (B,H,Sq,D); k/v (B,KH,Skv,D) -> (out (B,H,Sq,D), lse (B,H,Sq))."""
    from jax.experimental.pallas import tpu as pltpu

    B, H, Sq, D = q.shape
    KH, Skv = k.shape[1], k.shape[2]
    G = H // KH
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    nq, nk = Sq // bq, Skv // bk
    grid = (B, H, nq, nk)
    scale = 1.0 / np.sqrt(D)

    kernel = functools.partial(_fwd_kernel, causal=causal, window=window,
                               bq=bq, bk=bk, nk=nk, scale=scale)
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denominator
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# Backward: dk/dv kernel (grid minor dim sweeps q blocks)
# ---------------------------------------------------------------------------


def _dkdv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                 dk_ref, dv_ref, *, causal: bool, window: int, bq: int,
                 bk: int, nq: int, G: int, scale: float):
    h = pl.program_id(1)
    j = pl.program_id(2)
    i = pl.program_id(3)

    @pl.when((h % G == 0) & (i == 0))
    def _init():
        dk_ref[0, 0] = jnp.zeros_like(dk_ref[0, 0])
        dv_ref[0, 0] = jnp.zeros_like(dv_ref[0, 0])

    @pl.when(_tile_live(i, j, bq, bk, causal, window))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]                               # (bq,)
        delta = delta_ref[0, 0]                           # (bq,)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _tile_mask(i, j, bq, bk, causal, window)
        p = jnp.exp(jnp.where(mask, s, NEG) - lse[:, None])
        p = jnp.where(mask, p, 0.0)                       # (bq, bk)
        dv_ref[0, 0] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk_ref[0, 0] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def flash_dkdv(q, k, v, dout, lse, delta, *, causal: bool, window: int = 0,
               bq: int = 512, bk: int = 512, interpret: bool = True):
    B, H, Sq, D = q.shape
    KH, Skv = k.shape[1], k.shape[2]
    G = H // KH
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    nq, nk = Sq // bq, Skv // bk
    grid = (B, H, nk, nq)
    kernel = functools.partial(_dkdv_kernel, causal=causal, window=window,
                               bq=bq, bk=bk, nq=nq, G=G,
                               scale=1.0 / np.sqrt(D))
    dk, dv = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, j, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, i: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, i: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, j, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, j, i: (b, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b, h, j, i: (b, h, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, i: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, i: (b, h // G, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KH, Skv, D), jnp.float32),
            jax.ShapeDtypeStruct((B, KH, Skv, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, dout, lse, delta)
    return dk, dv


# ---------------------------------------------------------------------------
# Backward: dq kernel (grid minor dim sweeps kv blocks)
# ---------------------------------------------------------------------------


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *,
               causal: bool, window: int, bq: int, bk: int, nk: int,
               scale: float):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        dq_ref[0, 0] = jnp.zeros_like(dq_ref[0, 0])

    @pl.when(_tile_live(i, j, bq, bk, causal, window))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _tile_mask(i, j, bq, bk, causal, window)
        p = jnp.exp(jnp.where(mask, s, NEG) - lse[:, None])
        p = jnp.where(mask, p, 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dq_ref[0, 0] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def flash_dq(q, k, v, dout, lse, delta, *, causal: bool, window: int = 0,
             bq: int = 512, bk: int = 512, interpret: bool = True):
    B, H, Sq, D = q.shape
    KH, Skv = k.shape[1], k.shape[2]
    G = H // KH
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    nq, nk = Sq // bq, Skv // bk
    grid = (B, H, nq, nk)
    kernel = functools.partial(_dq_kernel, causal=causal, window=window,
                               bq=bq, bk=bk, nk=nk, scale=1.0 / np.sqrt(D))
    dq = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), jnp.float32),
        interpret=interpret,
    )(q, k, v, dout, lse, delta)
    return dq
