"""Pure-jnp oracle for the flash-attention kernel (kernel layout B,H,S,D)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool, window: int = 0):
    """q (B,H,Sq,D); k/v (B,KH,Skv,D); positions are arange."""
    B, H, Sq, D = q.shape
    KH, Skv = k.shape[1], k.shape[2]
    G = H // KH
    qg = q.reshape(B, KH, G, Sq, D).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32)) / np.sqrt(D)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    m = jnp.ones((Sq, Skv), bool)
    if causal:
        m = m & (kpos <= qpos)
    if window:
        m = m & (kpos > qpos - window)
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(m[None, None, None], p, 0.0)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)
