"""Jit-facing wrapper: custom-VJP flash attention backed by the Pallas
kernels, with model-layout (B, S, H, D) in/out and backend dispatch
(interpret=True off-TPU, compiled kernel on TPU).

Also derives the kernel's static per-tile DMA burst list from its
BlockSpec grid (``transactions``) — the FireBridge §IV data-movement
contract: the schedule IS the burst list, fed to core/transactions.py for
Fig. 8/9 profiling and to the online congestion link (§IV-C).
"""
from __future__ import annotations

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, window, bq, bk):
    out, _ = K.flash_fwd(q, k, v, causal=causal, window=window, bq=bq, bk=bk,
                         interpret=_interpret_default())
    return out


def _fwd(q, k, v, causal, window, bq, bk):
    out, lse = K.flash_fwd(q, k, v, causal=causal, window=window, bq=bq,
                           bk=bk, interpret=_interpret_default())
    return out, (q, k, v, out, lse)


def _bwd(causal, window, bq, bk, res, dout):
    q, k, v, out, lse = res
    interp = _interpret_default()
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                                    # (B,H,Sq)
    dk, dv = K.flash_dkdv(q, k, v, dout, lse, delta, causal=causal,
                          window=window, bq=bq, bk=bk, interpret=interp)
    dq = K.flash_dq(q, k, v, dout, lse, delta, causal=causal, window=window,
                    bq=bq, bk=bk, interpret=interp)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_fwd, _bwd)


def flash_attention(q, k, v, *, q_pos=None, kv_pos=None, causal=True,
                    window=0, bq=512, bk=512):
    """Model-layout entry point: q (B,S,H,D), k/v (B,S,KH,D).

    Positions are assumed to be arange (self-attention); q_pos/kv_pos are
    accepted for interface parity with repro.models.attention and ignored.
    """
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _flash(qt, kt, vt, causal, window, bq, bk)
    return out.transpose(0, 2, 1, 3)


def transactions(B: int, H: int, Sq: int, Sk: int, D: int, *,
                 bq: int = 512, bk: int = 512, causal: bool = True,
                 dtype_bytes: int = 2) -> List[Tuple[str, str, int, int]]:
    """Static per-tile HBM<->VMEM burst list implied by the fwd BlockSpecs.

    Returns [(engine, direction, address, nbytes)] in grid order — per q
    block one q-tile fetch, a k/v-tile fetch per live KV block (causally
    masked tiles are skipped, matching the kernel's pl.when predication),
    and one output-tile write.  This is the §IV "schedule is the burst
    list" contract used by MemoryBridge.log_burst_list and the congestion
    link (Fig. 8).
    """
    bq, bk = min(bq, Sq), min(bk, Sk)
    q_base = 0
    k_base = q_base + B * H * Sq * D * dtype_bytes
    v_base = k_base + B * H * Sk * D * dtype_bytes
    o_base = v_base + B * H * Sk * D * dtype_bytes
    q_tile = bq * D * dtype_bytes
    kv_tile = bk * D * dtype_bytes
    txs: List[Tuple[str, str, int, int]] = []
    for b in range(B):
        for h in range(H):
            bh_q = (b * H + h) * Sq * D * dtype_bytes
            bh_k = (b * H + h) * Sk * D * dtype_bytes
            for i in range(Sq // bq):
                txs.append(("dma_q", "read",
                            q_base + bh_q + i * q_tile, q_tile))
                for j in range(Sk // bk):
                    if causal and j * bk > (i + 1) * bq - 1:
                        continue                   # fully-masked tile skipped
                    txs.append(("dma_k", "read",
                                k_base + bh_k + j * kv_tile, kv_tile))
                    txs.append(("dma_v", "read",
                                v_base + bh_k + j * kv_tile, kv_tile))
                txs.append(("dma_o", "write",
                            o_base + bh_q + i * q_tile, q_tile))
    return txs
