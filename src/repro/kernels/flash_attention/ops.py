"""Jit-facing wrapper: custom-VJP flash attention backed by the Pallas
kernels, with model-layout (B, S, H, D) in/out and backend dispatch
(interpret=True off-TPU, compiled kernel on TPU)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, window, bq, bk):
    out, _ = K.flash_fwd(q, k, v, causal=causal, window=window, bq=bq, bk=bk,
                         interpret=_interpret_default())
    return out


def _fwd(q, k, v, causal, window, bq, bk):
    out, lse = K.flash_fwd(q, k, v, causal=causal, window=window, bq=bq,
                           bk=bk, interpret=_interpret_default())
    return out, (q, k, v, out, lse)


def _bwd(causal, window, bq, bk, res, dout):
    q, k, v, out, lse = res
    interp = _interpret_default()
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                                    # (B,H,Sq)
    dk, dv = K.flash_dkdv(q, k, v, dout, lse, delta, causal=causal,
                          window=window, bq=bq, bk=bk, interpret=interp)
    dq = K.flash_dq(q, k, v, dout, lse, delta, causal=causal, window=window,
                    bq=bq, bk=bk, interpret=interp)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_fwd, _bwd)


def flash_attention(q, k, v, *, q_pos=None, kv_pos=None, causal=True,
                    window=0, bq=512, bk=512):
    """Model-layout entry point: q (B,S,H,D), k/v (B,S,KH,D).

    Positions are assumed to be arange (self-attention); q_pos/kv_pos are
    accepted for interface parity with repro.models.attention and ignored.
    """
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _flash(qt, kt, vt, causal, window, bq, bk)
    return out.transpose(0, 2, 1, 3)
