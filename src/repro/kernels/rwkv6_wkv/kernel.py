"""Pallas TPU kernel for the RWKV-6 WKV recurrence (data-dependent decay).

Grid (B, H/hb, L/cl), chunk index minor-most; the (hb, K, V) f32 state is
VMEM-resident across chunks.  Within a chunk the exact per-step recurrence
runs in registers/VMEM via fori_loop — per-channel decays make the
linear-attention q/k exp-factorisation overflow-prone (see
repro/models/rwkv6.py), so the kernel keeps the exact form; the win over the
lax twin is purely memory locality (state never round-trips to HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, st_ref, state_s, *,
                nc: int, cl: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_s[...] = jnp.zeros_like(state_s)

    r = r_ref[0].astype(jnp.float32)          # (cl, hb, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)          # decay in (0,1)
    u = u_ref[...].astype(jnp.float32)        # (hb, K)

    def step(t, carry):
        state, ys = carry
        rt, kt, vt, wt = r[t], k[t], v[t], w[t]          # (hb, K)
        kv = kt[:, :, None] * vt[:, None, :]             # (hb, K, V)
        out = jnp.sum(rt[:, :, None] * (state + u[:, :, None] * kv), axis=1)
        state = wt[:, :, None] * state + kv
        ys = jax.lax.dynamic_update_slice_in_dim(ys, out[None], t, axis=0)
        return state, ys

    ys0 = jnp.zeros((cl,) + v.shape[1:], jnp.float32)
    state, ys = jax.lax.fori_loop(0, cl, step, (state_s[...], ys0))
    state_s[...] = state
    y_ref[0] = ys.astype(y_ref.dtype)

    @pl.when(c == nc - 1)
    def _done():
        st_ref[0] = state_s[...]


def wkv_scan(r, k, v, w, u, *, chunk: int = 16, hb: int = 8,
             interpret: bool = True):
    """r/k/v/w (B,L,H,K); u (H,K).  w is the per-step decay in (0,1).
    Returns (y (B,L,H,K) f32, final_state (B,H,K,K) f32)."""
    B, L, H, K = r.shape
    cl = min(chunk, L)
    hb = min(hb, H)
    assert L % cl == 0 and H % hb == 0
    grid = (B, H // hb, L // cl)
    y, st = pl.pallas_call(
        functools.partial(_wkv_kernel, nc=grid[2], cl=cl),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cl, hb, K), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, cl, hb, K), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, cl, hb, K), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, cl, hb, K), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((hb, K), lambda b, h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cl, hb, K), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, hb, K, K), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, H, K), jnp.float32),
            jax.ShapeDtypeStruct((B, H, K, K), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hb, K, K), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return y, st
