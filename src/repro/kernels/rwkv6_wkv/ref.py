"""Per-timestep oracle for the WKV-6 recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_scan_ref(r, k, v, w, u):
    """r/k/v/w (B,L,H,K); u (H,K).
    out_t = r_t . (S + u * k_t v_t^T); S = diag(w_t) S + k_t v_t^T."""
    B, L, H, K = r.shape
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(state, t):
        rt, kt, vt, wt = rf[:, t], kf[:, t], vf[:, t], wf[:, t]
        kv = kt[..., None] * vt[..., None, :]             # (B,H,K,V)
        out = jnp.einsum("bhk,bhkv->bhv", rt,
                         state + uf[None, :, :, None] * kv)
        state = wt[..., None] * state + kv
        return state, out

    state0 = jnp.zeros((B, H, K, K), jnp.float32)
    state, ys = jax.lax.scan(step, state0, jnp.arange(L))
    return ys.transpose(1, 0, 2, 3), state
