"""Jit wrapper for the WKV-6 kernel with backend dispatch."""
from __future__ import annotations

import jax

from repro.kernels.rwkv6_wkv.kernel import wkv_scan as _wkv_scan


def wkv_scan(r, k, v, w, u, *, chunk=16, hb=8):
    return _wkv_scan(r, k, v, w, u, chunk=chunk, hb=hb,
                     interpret=jax.default_backend() != "tpu")
