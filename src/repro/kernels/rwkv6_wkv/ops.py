"""Jit wrapper for the WKV-6 kernel with backend dispatch, plus the static
per-tile DMA burst list implied by its BlockSpec grid (the §IV "schedule is
the burst list" contract; consumed by the FireBridge memory bridge and the
online congestion link, Fig. 8)."""
from __future__ import annotations

from typing import List, Tuple

import jax

from repro.kernels.rwkv6_wkv.kernel import wkv_scan as _wkv_scan


def wkv_scan(r, k, v, w, u, *, chunk=16, hb=8):
    return _wkv_scan(r, k, v, w, u, chunk=chunk, hb=hb,
                     interpret=jax.default_backend() != "tpu")


def transactions(B: int, L: int, H: int, K: int, V: int = 0, *,
                 chunk: int = 16, hb: int = 8,
                 dtype_bytes: int = 4) -> List[Tuple[str, str, int, int]]:
    """Per-tile HBM bursts of the WKV grid (B, H/hb, L/chunk).

    Per grid cell: one r/k/v/w chunk fetch each and one y chunk write; per
    (batch, head-group) one u fetch and one final-state writeback.  The
    (hb, K, V) state stays VMEM-resident across the chunk sweep, so no
    dma_state traffic appears between chunks.
    """
    V = V or K
    chunk = min(chunk, L)
    groups = max(1, H // hb)
    r_base = 0
    span = B * L * H * K * dtype_bytes            # r/k/w each; v uses V
    k_base = r_base + span
    v_base = k_base + span
    w_base = v_base + B * L * H * V * dtype_bytes
    u_base = w_base + span
    y_base = u_base + H * K * dtype_bytes
    s_base = y_base + B * L * H * V * dtype_bytes
    rk_tile = chunk * hb * K * dtype_bytes
    v_tile = chunk * hb * V * dtype_bytes
    u_tile = hb * K * dtype_bytes
    state = hb * K * V * dtype_bytes
    txs: List[Tuple[str, str, int, int]] = []
    for b in range(B):
        for g in range(groups):
            txs.append(("dma_u", "read", u_base + g * u_tile, u_tile))
            for c in range(L // chunk):
                off = (b * groups + g) * (L // chunk) + c
                txs.append(("dma_r", "read",
                            r_base + off * rk_tile, rk_tile))
                txs.append(("dma_k", "read",
                            k_base + off * rk_tile, rk_tile))
                txs.append(("dma_v", "read", v_base + off * v_tile, v_tile))
                txs.append(("dma_w", "read",
                            w_base + off * rk_tile, rk_tile))
                txs.append(("dma_y", "write",
                            y_base + off * v_tile, v_tile))
            txs.append(("dma_state", "write",
                        s_base + (b * groups + g) * state, state))
    return txs
