"""Error-feedback int8 gradient compression (beyond-paper distributed-
optimization trick).

Blockwise symmetric int8 quantization with a persistent error-feedback
buffer (EF21-style): the quantization residual is carried into the next
step, so compression bias vanishes in expectation.  The trainer applies it
to the gradient before the ZeRO reduce-scatter; on the wire this is an 8x
reduction vs f32 when the manual shard_map DP path is enabled, and a pure
accuracy-preserving mechanism otherwise (property-tested: EF residual
bounds, determinism, scale safety).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize_leaf(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = g.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_leaf(q: jax.Array, scale: jax.Array, shape, size) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def compress_decompress(g: jax.Array) -> jax.Array:
    q, s = _quantize_leaf(g.astype(jnp.float32))
    return _dequantize_leaf(q, s, g.shape, g.size).astype(g.dtype)


def ef_compress(grads: Any, error: Any) -> Tuple[Any, Any]:
    """Returns (compressed grads, new error buffers)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        cq = compress_decompress(corrected)
        return cq.astype(g.dtype), corrected - cq

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tree, [o[0] for o in out]),
            jax.tree.unflatten(tree, [o[1] for o in out]))


def init_error(grads_shape: Any) -> Any:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32),
                        grads_shape)
