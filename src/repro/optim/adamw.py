"""AdamW with warmup+cosine schedule and global-norm clipping.

Written against plain pytrees (no optax dependency in this container).
Moments are f32 and share the parameter PartitionSpecs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any,
                 opt: dict) -> Tuple[Any, dict, dict]:
    step = opt["step"] + 1
    lr = lr_schedule(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
